"""Paper Tables 4–6 / Figures 7–8: IHTC + k-means / HAC on the six datasets.

Offline container ⇒ synthetic analogs with the exact (n, d, k) of Table 3.
Reports run time, working set, BSS/TSS and prototype counts per m — the
paper's claim is BSS/TSS preserved while time/memory drop."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_DATASETS, dataset_analog, live_mb, print_csv, timed
from repro.cluster.metrics import bss_tss
from repro.core import ihtc


def run(max_n: int = 200_000, ms=(0, 1, 2, 3), datasets=None, hac_ms=None):
    rows_km, rows_hac = [], []
    for spec in datasets or PAPER_DATASETS:
        x = dataset_analog(spec, max_n=max_n)
        xj = jnp.asarray(x)
        n = len(x)
        for m in ms:
            def work(xj=xj, m=m, spec=spec):  # bind loop vars (B023)
                return ihtc(xj, 2, m, "kmeans", k=spec.k,
                            key=jax.random.PRNGKey(1))
            res, sec = timed(work)
            ratio = float(bss_tss(xj, res.labels, spec.k))
            rows_km.append((spec.name, n, m, round(sec, 4),
                            round(live_mb(), 1), int(res.n_prototypes),
                            round(ratio, 4)))
        # HAC needs enough reduction first (Table 5/6 pattern)
        m0 = 0
        while n // (2**m0) > 4096:
            m0 += 1
        for m in (hac_ms or (m0, m0 + 1)):
            def work_h(xj=xj, m=m, spec=spec):  # bind loop vars (B023)
                return ihtc(xj, 2, m, "hac", k=spec.k, linkage="ward",
                            key=jax.random.PRNGKey(1))
            res, sec = timed(work_h)
            ratio = float(bss_tss(xj, res.labels, spec.k))
            rows_hac.append((spec.name, n, m, round(sec, 4),
                             round(live_mb(), 1), int(res.n_prototypes),
                             round(ratio, 4)))
    print_csv("table4_datasets_kmeans", rows_km,
              "dataset,n,m,seconds,live_mb,n_prototypes,bss_tss")
    print_csv("table5_datasets_hac", rows_hac,
              "dataset,n,m,seconds,live_mb,n_prototypes,bss_tss")
    return rows_km, rows_hac


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-n", type=int, default=200_000)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        run(max_n=20_000, ms=(0, 1, 2), datasets=PAPER_DATASETS[:2])
    else:
        run(max_n=args.max_n)


if __name__ == "__main__":
    main()
