"""Paper Table 1 / Figures 3–4: IHTC + k-means on the GMM simulation.

Sweeps data size n and ITIS iterations m (m=0 = plain k-means), reporting
run time, working-set MB, prototype count and prediction accuracy — the
paper's claim is ~2× time/memory at m=1 with accuracy preserved (~0.9239).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import gmm_sample, live_mb, print_csv, timed
from repro.cluster.metrics import clustering_accuracy
from repro.core import ihtc


def run(ns=(10_000, 100_000), ms=(0, 1, 2, 3, 4), t: int = 2, seed: int = 0):
    rows = []
    for n in ns:
        x, true = gmm_sample(n, seed)
        xj = jnp.asarray(x)
        for m in ms:
            def work(xj=xj, m=m):  # bind loop vars (B023)
                return ihtc(xj, t, m, "kmeans", k=3,
                            key=jax.random.PRNGKey(seed))
            res, sec = timed(work, warmup=1)
            acc = clustering_accuracy(true, np.asarray(res.labels), 3)
            rows.append((n, m, round(sec, 4), round(live_mb(), 1),
                         int(res.n_prototypes), round(acc, 4)))
    print_csv("table1_ihtc_kmeans", rows,
              "n,m,seconds,live_mb,n_prototypes,accuracy")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-n", type=int, default=100_000)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    ns = (2_000,) if args.quick else tuple(
        n for n in (10_000, 100_000, 1_000_000) if n <= args.max_n)
    ms = (0, 1, 2) if args.quick else (0, 1, 2, 3, 4, 6)
    run(ns=ns, ms=ms)


if __name__ == "__main__":
    main()
