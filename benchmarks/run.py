"""Benchmark driver: one harness per paper table (+ the LM-stack micro
benches, the distributed weak-scaling sweep, and the dry-run roofline
summary). Default mode is sized for a CPU container; pass --full for
paper-scale sweeps and --distributed for the multi-device IHTC sweep
(subprocesses with forced CPU device counts).

Output: `name,<row>` CSV per table on stdout (see each bench module's
header line). Harnesses that sweep an axis worth keeping (currently
bench_distributed) additionally record a trajectory artifact under
benchmarks/results/BENCH_<name>.json; this driver prints a one-line summary
per artifact at the end of every run. Schemas are documented in
docs/BENCHMARKS.md.
"""
from __future__ import annotations

import argparse
import os
import sys

# make `python benchmarks/run.py` work from anywhere: the repo root (for the
# benchmarks package) and src/ (for repro) both go on sys.path
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import time

import jax
import jax.numpy as jnp


def _lm_microbench(quick: bool = True):
    """LM-stack sanity perf: per-token train cost of smoke models."""
    from benchmarks.common import print_csv, timed
    from repro.configs import ARCHS, SHAPES, smoke_config
    from repro.data import make_batch
    from repro.models import build
    from repro.train import OptConfig, init_opt_state, make_train_step

    rows = []
    for name in ("qwen2.5-32b", "mamba2-370m", "jamba-v0.1-52b"):
        cfg = smoke_config(ARCHS[name])
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        step = jax.jit(make_train_step(bundle, OptConfig()))
        batch = make_batch(cfg, SHAPES["train_4k"], 0, batch_override=4,
                           seq_override=64)
        (_, _, m), sec = timed(lambda: step(params, opt, batch), warmup=1,
                               iters=3)
        us_per_tok = sec / (4 * 64) * 1e6
        rows.append((name, "train_step", round(sec * 1e3, 2),
                     round(us_per_tok, 2)))
    print_csv("lm_microbench", rows, "arch,phase,ms_per_step,us_per_token")


def _kernel_microbench():
    """Clustering hot-spot timings (oracle path on CPU)."""
    import numpy as np

    from benchmarks.common import print_csv, timed
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    rows = []
    x = jnp.asarray(rng.normal(size=(4096, 8)), jnp.float32)
    f = jax.jit(lambda a: ops.knn(a, 3, impl="ref"))
    _, sec = timed(f, x, warmup=1, iters=3)
    rows.append(("knn_4096x8_k3", round(sec * 1e3, 2),
                 round(sec / 4096 * 1e9, 1)))
    ids = jnp.asarray(rng.integers(0, 2048, size=4096), jnp.int32)
    g = jax.jit(lambda a, i: ops.segment_sum(a, i, 2048, impl="ref"))
    _, sec = timed(g, x, ids, warmup=1, iters=3)
    rows.append(("segment_sum_4096", round(sec * 1e3, 2),
                 round(sec / 4096 * 1e9, 1)))
    print_csv("kernel_microbench", rows, "kernel,ms,ns_per_point")


def _bench_json_summary() -> None:
    """One summary line per benchmarks/results/BENCH_*.json trajectory.

    Schema-flexible: the sweep axis / metric pair is picked per artifact
    (devices/seconds for the distributed sweep, batch/points_per_sec for
    the serving sweep — docs/BENCHMARKS.md)."""
    import glob
    import json

    axes = (("devices", "seconds"), ("batch", "points_per_sec"),
            ("n", "stream_peak_mb"))
    results = os.path.join(os.path.dirname(__file__), "results")
    for path in sorted(glob.glob(os.path.join(results, "BENCH_*.json"))):
        with open(path) as f:
            art = json.load(f)
        rows = art.get("rows", [])
        axis, metric = next(
            (a for a in axes if rows and a[0] in rows[0]), axes[0])
        xs = ",".join(str(r.get(axis, "?")) for r in rows)
        ys = ",".join(str(r.get(metric, "?")) for r in rows)
        print(f"# {os.path.basename(path)}: {art.get('name')} "
              f"mode={art.get('mode')} {axis}=[{xs}] {metric}=[{ys}]")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (hours on CPU)")
    ap.add_argument("--max-n", type=int, default=0)
    ap.add_argument("--distributed", action="store_true",
                    help="also run the multi-device weak-scaling sweep "
                         "(subprocesses with forced CPU device counts)")
    ap.add_argument("--serve", action="store_true",
                    help="also run the ClusterIndex.assign serving sweep")
    ap.add_argument("--streaming", action="store_true",
                    help="also run the out-of-core streaming-fit sweep")
    ap.add_argument("--summary-only", action="store_true",
                    help="skip every harness; just print the one-line "
                         "summary per recorded BENCH_*.json artifact")
    args, _ = ap.parse_known_args()
    quick = not args.full

    if args.summary_only:
        _bench_json_summary()
        return

    from benchmarks import (bench_table1_kmeans, bench_table2_hac,
                            bench_table4_datasets, bench_table7_threshold,
                            bench_table9_dbscan)
    from benchmarks.common import PAPER_DATASETS

    t0 = time.time()
    if quick:
        bench_table1_kmeans.run(ns=(2_000, 20_000), ms=(0, 1, 2, 3))
        bench_table2_hac.run(ns=(4_000,), budget=512)
        bench_table4_datasets.run(max_n=20_000, ms=(0, 1, 2),
                                  datasets=PAPER_DATASETS[:3])
        bench_table7_threshold.run(n=5_000, ts=(2, 4, 8, 16))
        bench_table9_dbscan.run(max_n=4_000, ms=(1, 2))
        _lm_microbench()
        _kernel_microbench()
        if args.distributed:
            from benchmarks import bench_distributed

            bench_distributed.run(n_per_device=4096)
        if args.serve:
            from benchmarks import bench_serve

            bench_serve.run(n=20_000, buckets=(32, 128, 512, 2048),
                            mode="quick")
        if args.streaming:
            from benchmarks import bench_streaming

            bench_streaming.run(ns=(8_192, 32_768), chunk=2_048,
                                inmem_max_n=32_768, mode="quick")
    else:
        mx = args.max_n or 1_000_000
        bench_table1_kmeans.run(
            ns=tuple(n for n in (10_000, 100_000, 1_000_000) if n <= mx))
        bench_table2_hac.run(
            ns=tuple(n for n in (10_000, 100_000, 1_000_000) if n <= mx))
        bench_table4_datasets.run(max_n=min(mx, 600_000))
        bench_table7_threshold.run(n=min(mx, 100_000))
        bench_table9_dbscan.run(max_n=min(mx, 50_000))
        _lm_microbench()
        _kernel_microbench()
        if args.distributed:
            from benchmarks import bench_distributed

            bench_distributed.run(n_per_device=min(mx, 65_536))
        if args.serve:
            from benchmarks import bench_serve

            bench_serve.run(n=min(mx, 1_000_000), m=3,
                            buckets=(32, 128, 512, 2048, 8192, 32_768),
                            mode="full")
        if args.streaming:
            from benchmarks import bench_streaming

            bench_streaming.run(
                ns=tuple(n for n in (65_536, 262_144, 1_048_576) if n <= mx)
                or (mx,),
                chunk=8_192, inmem_max_n=min(mx, 262_144), mode="full")

    # dry-run roofline summary, if artifacts exist
    results = os.path.join(os.path.dirname(__file__), "results", "dryrun")
    if os.path.isdir(results) and os.listdir(results):
        from benchmarks import roofline

        cells = roofline.load(results)
        ok = sum(1 for c in cells if c["status"] == "ok")
        skip = sum(1 for c in cells if c["status"] == "skip")
        err = sum(1 for c in cells if c["status"] not in ("ok", "skip"))
        print(f"# dryrun_cells: ok={ok} skip={skip} error={err}")
    _bench_json_summary()
    print(f"# total_bench_seconds,{round(time.time() - t0, 1)}")


if __name__ == "__main__":
    main()
