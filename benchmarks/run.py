"""Benchmark driver: one harness per paper table (+ the LM-stack micro
benches and the dry-run roofline summary), plus a **registry of optional
harnesses** discovered from the ``bench_*.py`` modules themselves.

Any ``benchmarks/bench_<name>.py`` that defines a module-level ``BENCH``
dict joins the registry with zero edits here::

    BENCH = {
        "name": "fit_matrix",                  # --bench fit_matrix
        "artifact": "BENCH_fit_matrix.json",   # results/ trajectory file
        "summary": ("n", "peak_mb"),           # axis/metric summary pair
        "quick": {...},                        # kwargs for run() (default)
        "full": lambda max_n: {...},           # kwargs for run() (--full)
    }

``--bench a,b`` runs the named harnesses after the core table suite;
``--bench all`` runs every discovered one; ``--list-benches`` prints the
registry; ``--bench a,b --gate`` runs them through the perf-regression
gate (benchmarks/gate.py) against the committed ``BENCH_*.json``
baselines instead — one command to run a registered bench and gate it. (This replaces the old hand-added ``--serve`` / ``--streaming``
/ ``--distributed`` flags — new executors get benchmarked by dropping in a
module, not by touching this driver.)

Output: `name,<row>` CSV per table on stdout (see each bench module's
header line). Harnesses that sweep an axis worth keeping record a
trajectory artifact under benchmarks/results/BENCH_<name>.json; this
driver prints a one-line summary per artifact at the end of every run,
using the registering module's ``summary`` hint when it has one. Schemas
are documented in docs/BENCHMARKS.md.
"""
from __future__ import annotations

import argparse
import functools
import glob as _glob
import importlib
import os
import sys

# make `python benchmarks/run.py` work from anywhere: the repo root (for the
# benchmarks package) and src/ (for repro) both go on sys.path
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import time


def discover_benches() -> dict:
    """name → registry spec for every bench_*.py exposing a ``BENCH`` dict.

    Discovery parses the source with ``ast`` instead of importing — bench
    modules pull in jax and the whole repro stack at module scope, which
    ``--summary-only`` / ``--list-benches`` must not pay for. Literal
    fields (``name``, ``artifact``, ``summary``) land in the spec; the
    module itself (for ``run()`` and the non-literal ``full`` lambda) is
    imported lazily by :func:`_run_registered` via the ``module_name``
    key."""
    import ast

    here = os.path.dirname(os.path.abspath(__file__))
    specs = {}
    for path in sorted(_glob.glob(os.path.join(here, "bench_*.py"))):
        stem = os.path.splitext(os.path.basename(path))[0]
        try:
            tree = ast.parse(open(path).read())
        except SyntaxError:
            continue
        for node in tree.body:
            if not (isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == "BENCH"
                    for t in node.targets)):
                continue
            if not isinstance(node.value, ast.Dict):
                continue
            spec = {"module_name": f"benchmarks.{stem}"}
            for k, v in zip(node.value.keys, node.value.values, strict=True):
                if isinstance(k, ast.Constant):
                    try:
                        spec[k.value] = ast.literal_eval(v)
                    except ValueError:  # lambdas etc.: import-time only
                        pass
            if "name" in spec:
                specs[spec["name"]] = spec
    return specs


def _lm_microbench(quick: bool = True):
    """LM-stack sanity perf: per-token train cost of smoke models."""
    import jax

    from benchmarks.common import print_csv, timed
    from repro.configs import ARCHS, SHAPES, smoke_config
    from repro.data import make_batch
    from repro.models import build
    from repro.train import OptConfig, init_opt_state, make_train_step

    rows = []
    for name in ("qwen2.5-32b", "mamba2-370m", "jamba-v0.1-52b"):
        cfg = smoke_config(ARCHS[name])
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        # repro: allow[RT303]: arch sweep — one compile per architecture is the intent; the wrapper is used immediately and discarded
        step = jax.jit(make_train_step(bundle, OptConfig()))
        batch = make_batch(cfg, SHAPES["train_4k"], 0, batch_override=4,
                           seq_override=64)
        (_, _, m), sec = timed(functools.partial(step, params, opt, batch),
                               warmup=1, iters=3)
        us_per_tok = sec / (4 * 64) * 1e6
        rows.append((name, "train_step", round(sec * 1e3, 2),
                     round(us_per_tok, 2)))
    print_csv("lm_microbench", rows, "arch,phase,ms_per_step,us_per_token")


def _kernel_microbench():
    """Clustering hot-spot timings (oracle path on CPU)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import print_csv, timed
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    rows = []
    x = jnp.asarray(rng.normal(size=(4096, 8)), jnp.float32)
    f = jax.jit(lambda a: ops.knn(a, 3, impl="ref"))
    _, sec = timed(f, x, warmup=1, iters=3)
    rows.append(("knn_4096x8_k3", round(sec * 1e3, 2),
                 round(sec / 4096 * 1e9, 1)))
    ids = jnp.asarray(rng.integers(0, 2048, size=4096), jnp.int32)
    g = jax.jit(lambda a, i: ops.segment_sum(a, i, 2048, impl="ref"))
    _, sec = timed(g, x, ids, warmup=1, iters=3)
    rows.append(("segment_sum_4096", round(sec * 1e3, 2),
                 round(sec / 4096 * 1e9, 1)))
    print_csv("kernel_microbench", rows, "kernel,ms,ns_per_point")


# fallback (axis, metric) pairs for artifacts whose writer predates the
# registry's per-module ``summary`` hint
_SUMMARY_AXES = (("devices", "seconds"), ("batch", "points_per_sec"),
                 ("n", "stream_peak_mb"), ("n", "peak_mb"))


def _bench_json_summary(specs: dict) -> None:
    """One summary line per benchmarks/results/BENCH_*.json trajectory.

    The sweep axis / metric pair comes from the registering module's
    ``summary`` hint when the artifact belongs to a registered harness,
    falling back to schema sniffing for anything else (docs/BENCHMARKS.md).
    """
    import json

    hints = {spec["artifact"]: spec.get("summary")
             for spec in specs.values() if spec.get("artifact")}
    results = os.path.join(os.path.dirname(__file__), "results")
    for path in sorted(_glob.glob(os.path.join(results, "BENCH_*.json"))):
        with open(path) as f:
            art = json.load(f)
        rows = art.get("rows", [])
        pair = hints.get(os.path.basename(path))
        if not (pair and rows and pair[0] in rows[0]):
            pair = next(
                (a for a in _SUMMARY_AXES if rows and a[0] in rows[0]),
                _SUMMARY_AXES[0])
        axis, metric = pair
        xs = ",".join(str(r.get(axis, "?")) for r in rows)
        ys = ",".join(str(r.get(metric, "?")) for r in rows)
        print(f"# {os.path.basename(path)}: {art.get('name')} "
              f"mode={art.get('mode')} {axis}=[{xs}] {metric}=[{ys}]")


def _run_registered(specs: dict, names, full: bool, max_n: int) -> None:
    for name in names:
        if name not in specs:
            print(f"# unknown bench {name!r}; have {sorted(specs)}",
                  file=sys.stderr)
            continue
        mod = importlib.import_module(specs[name]["module_name"])
        bench = getattr(mod, "BENCH", {})
        kwargs = bench.get("full") if full else bench.get("quick", {})
        if callable(kwargs):
            kwargs = kwargs(max_n)
        print(f"# bench {name}: {mod.__name__}.run("
              + ", ".join(f"{k}={v!r}" for k, v in (kwargs or {}).items())
              + ")")
        mod.run(**(kwargs or {}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (hours on CPU)")
    ap.add_argument("--max-n", type=int, default=0)
    ap.add_argument("--bench", type=str, default="",
                    help="comma list of registered harnesses to run after "
                         "the core suite (or 'all'); see --list-benches")
    ap.add_argument("--gate", action="store_true",
                    help="run the named --bench harness(es) through the "
                         "perf-regression gate (benchmarks/gate.py) instead "
                         "of a plain run; skips the core table suite and "
                         "exits nonzero on a regression vs the committed "
                         "BENCH_*.json baselines")
    ap.add_argument("--gate-repeats", type=int, default=1,
                    help="with --gate: runs per harness (per-cell medians)")
    ap.add_argument("--gate-default-tol", type=float, default=None,
                    help="with --gate: one relative tolerance for every "
                         "metric (gate.py --default-tol)")
    ap.add_argument("--list-benches", action="store_true",
                    help="print the discovered bench registry and exit")
    ap.add_argument("--summary-only", action="store_true",
                    help="skip every harness; just print the one-line "
                         "summary per recorded BENCH_*.json artifact")
    args, _ = ap.parse_known_args()
    quick = not args.full

    specs = discover_benches()
    if args.list_benches:
        for name, spec in sorted(specs.items()):
            print(f"{name}: {spec['module_name']} "
                  f"(artifact {spec.get('artifact', '-')})")
        return
    if args.summary_only:
        _bench_json_summary(specs)
        return
    if args.gate:
        if not args.bench:
            ap.error("--gate needs --bench (which registered harnesses "
                     "to run and gate)")
        from benchmarks import gate

        names = (sorted(s for s in specs if specs[s].get("artifact"))
                 if args.bench.strip() == "all"
                 else [n.strip() for n in args.bench.split(",") if n.strip()])
        rc = 0
        for name in names:
            rc = max(rc, gate.gate_bench(
                name, full=args.full, max_n=args.max_n or 1_000_000,
                repeats=args.gate_repeats,
                default_tol=args.gate_default_tol))
        sys.exit(rc)

    from benchmarks import (bench_table1_kmeans, bench_table2_hac,
                            bench_table4_datasets, bench_table7_threshold,
                            bench_table9_dbscan)
    from benchmarks.common import PAPER_DATASETS

    t0 = time.time()
    if quick:
        bench_table1_kmeans.run(ns=(2_000, 20_000), ms=(0, 1, 2, 3))
        bench_table2_hac.run(ns=(4_000,), budget=512)
        bench_table4_datasets.run(max_n=20_000, ms=(0, 1, 2),
                                  datasets=PAPER_DATASETS[:3])
        bench_table7_threshold.run(n=5_000, ts=(2, 4, 8, 16))
        bench_table9_dbscan.run(max_n=4_000, ms=(1, 2))
        _lm_microbench()
        _kernel_microbench()
    else:
        mx = args.max_n or 1_000_000
        bench_table1_kmeans.run(
            ns=tuple(n for n in (10_000, 100_000, 1_000_000) if n <= mx))
        bench_table2_hac.run(
            ns=tuple(n for n in (10_000, 100_000, 1_000_000) if n <= mx))
        bench_table4_datasets.run(max_n=min(mx, 600_000))
        bench_table7_threshold.run(n=min(mx, 100_000))
        bench_table9_dbscan.run(max_n=min(mx, 50_000))
        _lm_microbench()
        _kernel_microbench()

    if args.bench:
        names = (sorted(specs) if args.bench.strip() == "all"
                 else [n.strip() for n in args.bench.split(",") if n.strip()])
        _run_registered(specs, names, args.full,
                        args.max_n or 1_000_000)

    # dry-run roofline summary, if artifacts exist
    results = os.path.join(os.path.dirname(__file__), "results", "dryrun")
    if os.path.isdir(results) and os.listdir(results):
        from benchmarks import roofline

        cells = roofline.load(results)
        ok = sum(1 for c in cells if c["status"] == "ok")
        skip = sum(1 for c in cells if c["status"] == "skip")
        err = sum(1 for c in cells if c["status"] not in ("ok", "skip"))
        print(f"# dryrun_cells: ok={ok} skip={skip} error={err}")
    _bench_json_summary(specs)
    print(f"# total_bench_seconds,{round(time.time() - t0, 1)}")


if __name__ == "__main__":
    main()
