"""Paper Table 2 / Figures 5–6: IHTC + HAC on the GMM simulation.

HAC is O(n² log n) / O(n²) memory — the paper's point is that it is simply
infeasible beyond ~2¹⁶ points without IHTC, and cheap after enough ITIS
iterations. We report the minimum feasible m per n (prototype count must
drop below the HAC budget) plus time/accuracy, mirroring Table 2's
diagonal band of populated cells.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import gmm_sample, live_mb, print_csv, timed
from repro.cluster.metrics import clustering_accuracy
from repro.core import ihtc

HAC_BUDGET = 4096  # max points our dense Lance-Williams HAC should see


def run(ns=(10_000, 100_000), t: int = 2, seed: int = 0, budget=HAC_BUDGET):
    rows = []
    for n in ns:
        x, true = gmm_sample(n, seed)
        xj = jnp.asarray(x)
        m = 0
        # find the first m whose prototype count fits the HAC budget (the
        # paper's "feasibility frontier"), then run a couple beyond it
        while n // (t**m) > budget:
            m += 1
        for mm in (m, m + 1, m + 2):
            def work(xj=xj, mm=mm):  # bind loop vars (B023)
                return ihtc(xj, t, mm, "hac", k=3, linkage="ward",
                            key=jax.random.PRNGKey(seed))
            res, sec = timed(work, warmup=1)
            acc = clustering_accuracy(true, np.asarray(res.labels), 3)
            rows.append((n, mm, round(sec, 4), round(live_mb(), 1),
                         int(res.n_prototypes), round(acc, 4)))
    print_csv("table2_ihtc_hac", rows,
              "n,m,seconds,live_mb,n_prototypes,accuracy")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-n", type=int, default=100_000)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    ns = (4_000,) if args.quick else tuple(
        n for n in (10_000, 100_000, 1_000_000) if n <= args.max_n)
    run(ns=ns, budget=512 if args.quick else HAC_BUDGET)


if __name__ == "__main__":
    main()
