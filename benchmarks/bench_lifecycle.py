"""Open-loop traffic across a zero-downtime index refresh (DESIGN.md §19).

``bench_serve_async.py`` measures the async front-end in steady state;
this harness measures the *lifecycle*: requests flow while an
:class:`repro.serve.lifecycle.RefreshDriver` folds drifted traffic into a
live :class:`OnlineFitter` and hot-swaps the refreshed index into the
serving :class:`AsyncClusterService` mid-run. Three phases per offered
rate, each reported as its own row (the per-phase counters come from the
``stats_snapshot(reset=True)`` satellite):

* ``steady`` — the fresh-fit baseline, no refresh;
* ``swap``   — the same offered load with the snapshot → save → warmup →
  install pipeline firing mid-phase; ``swap_ms`` is the wall time the
  swap pipeline holds the event loop, ``swap_stall_p99_ms`` the p99
  latency of the requests in flight while it runs (the stall a client
  actually sees);
* ``post``   — drifted traffic on the refreshed index; ``dist_ratio``
  is the refreshed-vs-stale mean assign distance on the drifted
  distribution (quality recovered by the refresh — well under 1.0).

Artifact: ``benchmarks/results/BENCH_lifecycle.json``, gated by
``benchmarks/gate.py`` with row identity on (phase, offered_qps) and wide
tolerances on the swap-stall metrics (docs/BENCHMARKS.md).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys

# direct-run support: repo root for the benchmarks package, src/ for repro
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax.numpy as jnp
import numpy as np

from benchmarks.common import gmm_sample, print_csv
from repro.core.index import nearest_valid_prototype
from repro.serve import (AsyncClusterService, OnlineFitter, QueueFullError,
                         RefreshDriver, RefreshPolicy)

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")

# benchmark-registry entry (benchmarks/run.py --bench lifecycle)
BENCH = {
    "name": "lifecycle",
    "artifact": "BENCH_lifecycle.json",
    "summary": ("offered_qps", "p99_ms"),
    "quick": dict(n=6_000, duration=1.2, qps_levels=(100,), mode="quick"),
    "full": lambda mx: dict(n=min(mx, 200_000), duration=6.0,
                            qps_levels=(200, 1_000),
                            buckets=(32, 128, 512), mode="full"),
}

SIZES = (1, 4, 16, 64)
DRIFT_SHIFT = 6.0  # how far the traffic distribution moves


async def _phase(service, pool, *, qps: float, duration: float, seed: int,
                 fire_at: float = -1.0, fire=None):
    """Offered load at ``qps`` for ``duration`` seconds; optionally call
    ``fire()`` (loop-blocking, e.g. the refresh pipeline) at ``fire_at``.
    Returns (records, rejected, span_s, swap window)."""
    loop = asyncio.get_running_loop()
    rng = np.random.default_rng(seed)
    records, rejected = [], 0
    swap_t0 = swap_t1 = None
    t0 = loop.time()
    next_t, i, fired = 0.0, 0, fire is None
    while next_t < duration:
        if not fired and next_t >= fire_at:
            fired = True
            swap_t0 = loop.time()
            fire()
            swap_t1 = loop.time()
        gap = t0 + next_t - loop.time()
        if gap > 0:
            await asyncio.sleep(gap)
        size = SIZES[i % len(SIZES)]
        lo = int(rng.integers(0, pool.shape[0] - size))
        record = {"n": size, "t_submit": loop.time(), "t_done": None}
        try:
            fut = service.submit(pool[lo:lo + size])
        except QueueFullError:
            rejected += 1
        else:
            fut.add_done_callback(
                lambda _f, record=record: record.__setitem__(
                    "t_done", loop.time()))
            records.append(record)
        i += 1
        next_t += 1.0 / qps  # open loop: the schedule never backs off
    # settle in-flight work without draining (the service survives phases)
    while any(r["t_done"] is None for r in records):
        await asyncio.sleep(0.005)
    window = (swap_t0, swap_t1) if swap_t0 is not None else None
    return records, rejected, loop.time() - t0, window


def _lat_ms(records):
    return np.array([(r["t_done"] - r["t_submit"]) * 1e3 for r in records
                     if r["t_done"] is not None])


def _mean_dist(index, queries) -> float:
    d, _ = nearest_valid_prototype(jnp.asarray(queries), index.protos,
                                   index.proto_valid)
    return float(jnp.mean(jnp.sqrt(jnp.maximum(d, 0.0))))


def run(
    n: int = 6_000,
    t: int = 2,
    m: int = 2,
    backend: str = "kmeans",
    buckets=(32, 128, 512),
    duration: float = 1.2,
    qps_levels=(100,),
    max_wait_ms: float = 2.0,
    max_inflight: int = 4,
    observe_points: int = 2_000,
    seed: int = 0,
    mode: str = "quick",
):
    x, _ = gmm_sample(n, seed)
    drifted_pool = gmm_sample(4096, seed + 1)[0] + DRIFT_SHIFT
    home_pool = gmm_sample(4096, seed + 2)[0]

    rows = []
    for qps in qps_levels:
        fitter = OnlineFitter(x, t, m, backend, k=3,
                              chunk_n=max(observe_points, 1024))
        stale = fitter.build_index()
        service = AsyncClusterService(
            stale, buckets=buckets, max_wait=max_wait_ms / 1e3,
            max_inflight=max_inflight)
        driver = RefreshDriver(service, fitter, policy=RefreshPolicy())

        def phase_row(phase, records, rejected, span_s):
            lat = _lat_ms(records)
            sched = service.stats_snapshot(reset=True)["scheduler"]
            return {
                "phase": phase,
                "offered_qps": int(qps),
                "p50_ms": round(float(np.percentile(lat, 50)), 3),
                "p99_ms": round(float(np.percentile(lat, 99)), 3),
                "qps": round(len(lat) / max(span_s, 1e-9), 1),
                "batches": sched["batches"],
                "swaps": sched["swaps"],
                "rejected": rejected,
            }

        # phase 1: steady state on the freshly fitted index
        service.stats_snapshot(reset=True)
        records, rejected, span, _ = asyncio.run(_phase(
            service, home_pool, qps=qps, duration=duration, seed=seed + 3))
        rows.append(phase_row("steady", records, rejected, span))

        # fold drifted evidence in ahead of the timed swap (the observe
        # path is the fitter's cost; the swap phase isolates the install)
        rng = np.random.default_rng(seed + 4)
        driver.fitter.observe(
            drifted_pool[rng.integers(0, drifted_pool.shape[0],
                                      size=observe_points)])

        # phase 2: same load, refresh pipeline fires mid-phase
        records, rejected, span, window = asyncio.run(_phase(
            service, drifted_pool, qps=qps, duration=duration,
            seed=seed + 5, fire_at=duration / 2,
            fire=lambda: driver.refresh(trigger="bench")))
        swap_ms = (window[1] - window[0]) * 1e3
        # the stall a client saw: requests in flight while the swap
        # pipeline held the loop (submitted before it ended, done after
        # it began)
        stalled = _lat_ms([
            r for r in records if r["t_done"] is not None
            and r["t_submit"] <= window[1] and r["t_done"] >= window[0]])
        row = phase_row("swap", records, rejected, span)
        row["swap_ms"] = round(swap_ms, 3)
        row["swap_stall_p99_ms"] = round(
            float(np.percentile(stalled, 99)), 3) if stalled.size else 0.0
        rows.append(row)

        # phase 3: drifted traffic on the refreshed index + quality delta
        records, rejected, span, _ = asyncio.run(_phase(
            service, drifted_pool, qps=qps, duration=duration,
            seed=seed + 6))
        fresh = service.current_index()
        row = phase_row("post", records, rejected, span)
        row["dist_ratio"] = round(
            _mean_dist(fresh, drifted_pool)
            / max(_mean_dist(stale, drifted_pool), 1e-12), 4)
        rows.append(row)

        async def _shutdown(svc=service):
            await svc.drain()

        asyncio.run(_shutdown())

    print_csv(
        "lifecycle",
        [(r["phase"], r["offered_qps"], r["p50_ms"], r["p99_ms"], r["qps"],
          r["batches"], r["swaps"], r.get("swap_ms", ""),
          r.get("swap_stall_p99_ms", ""), r.get("dist_ratio", ""))
         for r in rows],
        "phase,offered_qps,p50_ms,p99_ms,qps,batches,swaps,swap_ms,"
        "swap_stall_p99_ms,dist_ratio")

    os.makedirs(RESULTS, exist_ok=True)
    art = {
        "name": "lifecycle",
        "mode": mode,
        "fit": {"n": n, "t": t, "m": m, "backend": backend},
        "config": {"buckets": list(buckets), "duration": duration,
                   "max_wait_ms": max_wait_ms, "max_inflight": max_inflight,
                   "observe_points": observe_points,
                   "drift_shift": DRIFT_SHIFT, "sizes": list(SIZES)},
        "rows": rows,
    }
    with open(os.path.join(RESULTS, "BENCH_lifecycle.json"), "w") as f:
        json.dump(art, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=6_000)
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--duration", type=float, default=1.2,
                    help="seconds of offered load per phase")
    ap.add_argument("--qps", type=int, nargs="+", default=[100])
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--observe-points", type=int, default=2_000)
    ap.add_argument("--quick", action="store_true",
                    help="run the registered quick-mode sweep")
    args = ap.parse_args()
    if args.quick:
        run(**BENCH["quick"])
    else:
        run(n=args.n, t=args.t, m=args.m, duration=args.duration,
            qps_levels=tuple(args.qps), max_wait_ms=args.max_wait_ms,
            observe_points=args.observe_points, mode="cli")


if __name__ == "__main__":
    main()
