"""Open-loop load generator over the async continuous-batching front-end.

``bench_serve.py`` measures the per-bucket assign path in isolation; this
harness measures what a *user population* sees: requests of mixed sizes
arrive at a fixed offered rate (open loop — arrivals never wait for
completions, exactly how overload reaches a real service), flow through
:class:`repro.serve.AsyncClusterService` under real asyncio, and each
records its own admission→labels-materialized latency. Per offered-QPS
level we report p50/p99 latency, sustained request + point throughput,
and batch-fill telemetry into ``benchmarks/results/BENCH_serve_async.json``
— gated by ``benchmarks/gate.py`` (METRIC_RULES) so a serving-latency or
throughput regression fails CI.

The deterministic twin of this workload — same scheduler, virtual clock —
lives in ``tests/serve_sim.py`` / ``tests/test_async_service.py``.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys

# direct-run support: repo root for the benchmarks package, src/ for repro
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import gmm_sample, print_csv
from repro.cluster.registry import available_backends
from repro.core.index import ClusterIndex
from repro.serve.async_service import AsyncClusterService, QueueFullError

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")

# benchmark-registry entry (benchmarks/run.py --bench serve_async)
BENCH = {
    "name": "serve_async",
    "artifact": "BENCH_serve_async.json",
    "summary": ("offered_qps", "p99_ms"),
    "quick": dict(n=8_000, duration=1.5, qps_levels=(100, 400),
                  mode="quick"),
    "full": lambda mx: dict(n=min(mx, 500_000), m=3, duration=10.0,
                            qps_levels=(200, 1_000, 4_000),
                            buckets=(32, 128, 512, 2048), mode="full"),
}

#: request-size mix cycled by the generator (mean ≈ 21 points/request)
SIZES = (1, 4, 16, 64)


async def _open_loop(service, pool, *, qps: float, duration: float,
                     seed: int):
    """Fire requests at the offered rate for ``duration`` seconds, then
    drain. Returns (per-request records, rejected count, t0, t_end)."""
    loop = asyncio.get_running_loop()
    rng = np.random.default_rng(seed)
    records, rejected = [], 0
    t0 = loop.time()
    next_t, i = 0.0, 0
    while next_t < duration:
        gap = t0 + next_t - loop.time()
        if gap > 0:
            await asyncio.sleep(gap)
        size = SIZES[i % len(SIZES)]
        lo = int(rng.integers(0, pool.shape[0] - size))
        record = {"n": size, "t_submit": loop.time(), "t_done": None}
        try:
            fut = service.submit(pool[lo:lo + size])
        except QueueFullError:
            rejected += 1
        else:
            fut.add_done_callback(
                lambda _f, record=record: record.__setitem__(
                    "t_done", loop.time()))
            records.append(record)
        i += 1
        next_t += 1.0 / qps  # open loop: the schedule never backs off
    await service.drain()
    return records, rejected, t0, loop.time()


def run(
    n: int = 8_000,
    t: int = 2,
    m: int = 2,
    backend: str = "kmeans",
    buckets=(32, 128, 512),
    duration: float = 1.5,
    qps_levels=(100, 400),
    max_wait_ms: float = 2.0,
    max_inflight: int = 4,
    queue_depth: int = 100_000,
    block: int = 0,
    seed: int = 0,
    mode: str = "quick",
):
    x, _ = gmm_sample(n, seed)
    index = ClusterIndex.build(jnp.asarray(x), t, m, backend, k=3,
                               key=jax.random.PRNGKey(seed))
    pool = gmm_sample(4096, seed + 1)[0]

    rows = []
    for qps in qps_levels:
        fills = []
        service = AsyncClusterService(
            index, buckets=buckets, block=block,
            max_wait=max_wait_ms / 1e3, max_inflight=max_inflight,
            queue_depth=queue_depth,
            observer=lambda rec, fills=fills:  # bind loop var (B023)
                fills.append(rec.total / rec.bucket))
        records, rejected, t0, t_end = asyncio.run(
            _open_loop(service, pool, qps=qps, duration=duration,
                       seed=seed + 2))
        done = [r for r in records if r["t_done"] is not None]
        lat_ms = np.array([(r["t_done"] - r["t_submit"]) * 1e3
                           for r in done])
        span = max(t_end - t0, 1e-9)
        stats = service.stats
        rows.append({
            "offered_qps": int(qps),
            "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
            "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
            "qps": round(len(done) / span, 1),
            "points_per_sec": round(sum(r["n"] for r in done) / span),
            "batches": stats["batches"],
            "rejected": rejected,
            "mean_batch_fill": round(float(np.mean(fills)), 3) if fills
            else 0.0,
        })

    print_csv(
        "serve_async",
        [(r["offered_qps"], r["p50_ms"], r["p99_ms"], r["qps"],
          r["points_per_sec"], r["batches"], r["mean_batch_fill"],
          r["rejected"]) for r in rows],
        "offered_qps,p50_ms,p99_ms,qps,points_per_sec,batches,"
        "mean_batch_fill,rejected")

    os.makedirs(RESULTS, exist_ok=True)
    art = {
        "name": "serve_async",
        "mode": mode,
        "fit": {"n": n, "t": t, "m": m, "backend": backend,
                "n_prototypes": int(index.n_prototypes)},
        "config": {"buckets": list(buckets), "duration": duration,
                   "max_wait_ms": max_wait_ms, "max_inflight": max_inflight,
                   "queue_depth": queue_depth, "sizes": list(SIZES)},
        "rows": rows,
    }
    with open(os.path.join(RESULTS, "BENCH_serve_async.json"), "w") as f:
        json.dump(art, f, indent=1)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8_000)
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--backend", choices=available_backends(),
                    default="kmeans")
    ap.add_argument("--duration", type=float, default=1.5,
                    help="seconds of offered load per QPS level")
    ap.add_argument("--qps", type=int, nargs="+", default=[100, 400],
                    help="offered request rates to sweep")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--max-inflight", type=int, default=4)
    ap.add_argument("--quick", action="store_true",
                    help="run the registered quick-mode sweep")
    args = ap.parse_args()
    if args.quick:
        run(**BENCH["quick"])
    else:
        run(n=args.n, t=args.t, m=args.m, backend=args.backend,
            duration=args.duration, qps_levels=tuple(args.qps),
            max_wait_ms=args.max_wait_ms, max_inflight=args.max_inflight,
            mode="cli")


if __name__ == "__main__":
    main()
