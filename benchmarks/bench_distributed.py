"""Weak-scaling harness for the sharded IHTC pipeline (DESIGN.md §4).

Sweeps the device count on a forced-multi-device CPU host (the same
``--xla_force_host_platform_device_count`` trick the distribution tests
use): for each device count P a fresh subprocess streams a GMM point cloud
onto a 1-D ``data`` mesh and runs the end-to-end sharded IHTC
(ring-kNN TC → distributed prototype reduce → mesh-aware k-means).

Weak scaling holds n/P fixed (default 8192 points per device, so perfect
scaling is a flat wall-time line); ``--strong`` holds n fixed instead.

Output: one ``distributed_ihtc`` CSV block on stdout (the format every
``bench_table*.py`` uses, consumed by ``benchmarks/run.py``) plus a
``benchmarks/results/BENCH_distributed.json`` trajectory artifact — see
docs/BENCHMARKS.md for the schema and how run.py summarizes these files.

    python benchmarks/run.py --distributed      # via the driver
    python -m benchmarks.bench_distributed      # standalone sweep
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# benchmark-registry entry (benchmarks/run.py --bench distributed)
BENCH = {
    "name": "distributed",
    "artifact": "BENCH_distributed.json",
    "summary": ("devices", "seconds"),
    "quick": dict(n_per_device=4096),
    "full": lambda mx: dict(n_per_device=min(mx, 65_536)),
}


def _child(devices: int, n: int, t: int, m: int, k: int) -> None:
    """Runs in a subprocess with ``devices`` forced CPU devices; prints one
    JSON result line prefixed with ``RESULT:``."""
    import jax
    import numpy as np

    from benchmarks.common import timed
    from repro.core.distributed import ihtc_sharded, make_data_mesh
    from repro.data import PointStreamConfig, point_chunks, stream_to_mesh

    assert len(jax.devices()) == devices, (len(jax.devices()), devices)
    mesh = make_data_mesh()
    cfg = PointStreamConfig(n=n, d=2, chunk=min(n, 65_536), seed=0,
                            kind="gmm")
    t0 = time.perf_counter()
    x, valid = stream_to_mesh(point_chunks(cfg), mesh, cfg.n, cfg.d)
    ingest_s = time.perf_counter() - t0

    def work():
        return ihtc_sharded(x, t, m, "kmeans", k=k, valid=valid, mesh=mesh,
                            key=jax.random.PRNGKey(0))

    res, sec = timed(work, warmup=1, iters=1)
    lab = np.asarray(res.labels)[np.asarray(valid)]
    out = {
        "devices": devices,
        "n": n,
        "n_per_device": n // devices,
        "seconds": round(sec, 4),
        "ingest_seconds": round(ingest_s, 4),
        "n_prototypes": int(res.n_prototypes),
        "clusters": int(len(np.unique(lab[lab >= 0]))),
        "all_assigned": bool((lab >= 0).all()),
    }
    print("RESULT:" + json.dumps(out))


def run(device_counts=(1, 2, 4, 8), n_per_device: int = 8192, *,
        strong_n: int = 0, t: int = 2, m: int = 2, k: int = 3,
        out_path: str = "") -> list:
    """Sweep device counts in subprocesses; returns the per-count rows."""
    from benchmarks.common import print_csv

    rows = []
    for p in device_counts:
        n = strong_n if strong_n else n_per_device * p
        env = dict(
            os.environ,
            XLA_FLAGS=f"--xla_force_host_platform_device_count={p}",
            JAX_PLATFORMS="cpu",
            PYTHONPATH=os.pathsep.join(
                [os.path.join(_REPO, "src"), _REPO,
                 os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep),
        )
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_distributed", "--_child",
             str(p), "--n", str(n), "--t", str(t), "--m", str(m),
             "--k", str(k)],
            capture_output=True, text=True, timeout=1800, env=env, cwd=_REPO,
        )
        if proc.returncode != 0:
            print(f"# bench_distributed: devices={p} FAILED\n{proc.stderr}",
                  file=sys.stderr)
            continue
        line = next(l for l in proc.stdout.splitlines()
                    if l.startswith("RESULT:"))
        rows.append(json.loads(line[len("RESULT:"):]))

    print_csv(
        "distributed_ihtc",
        [(r["devices"], r["n"], r["seconds"], r["ingest_seconds"],
          r["n_prototypes"], r["clusters"]) for r in rows],
        "devices,n,seconds,ingest_seconds,n_prototypes,clusters",
    )

    mode = "strong" if strong_n else "weak"
    artifact = {
        "name": "distributed_ihtc",
        "mode": mode,
        "t": t, "m": m, "k": k,
        "recorded_unix": round(time.time(), 1),
        "rows": rows,
    }
    path = out_path or os.path.join(RESULTS_DIR, "BENCH_distributed.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    print(f"# wrote {os.path.relpath(path, _REPO)}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--_child", type=int, default=0, help=argparse.SUPPRESS)
    ap.add_argument("--n", type=int, default=0)
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--devices", type=str, default="1,2,4,8")
    ap.add_argument("--n-per-device", type=int, default=8192)
    ap.add_argument("--strong", action="store_true",
                    help="fix total n (=--n) instead of n per device")
    args = ap.parse_args()

    if args._child:
        _child(args._child, args.n, args.t, args.m, args.k)
        return
    counts = tuple(int(c) for c in args.devices.split(","))
    run(counts, args.n_per_device,
        strong_n=(args.n or args.n_per_device * max(counts)) if args.strong
        else 0,
        t=args.t, m=args.m, k=args.k)


if __name__ == "__main__":
    main()
