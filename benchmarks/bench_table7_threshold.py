"""Paper Tables 7–8 / Figures 9–11 (Appendix A): one ITIS iteration (m=1)
at varying threshold t*. The paper finds: small t* cuts time/memory with
flat accuracy; large t* eventually costs more time than no preprocessing
(the kNN graph construction scales with t*)."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import gmm_sample, live_mb, print_csv, timed
from repro.cluster.metrics import clustering_accuracy
from repro.core import ihtc


def run(n=100_000, ts=(2, 4, 8, 16, 32, 64), seed: int = 0):
    x, true = gmm_sample(n, seed)
    xj = jnp.asarray(x)
    rows = []
    # the t*=None (no preprocessing) baseline
    res, sec = timed(lambda: ihtc(xj, 2, 0, "kmeans", k=3,
                                  key=jax.random.PRNGKey(seed)))
    acc = clustering_accuracy(true, np.asarray(res.labels), 3)
    rows.append((n, "none", round(sec, 4), round(live_mb(), 1), n,
                 round(acc, 4)))
    for t in ts:
        def work(t=t):  # bind the loop var (B023)
            return ihtc(xj, t, 1, "kmeans", k=3, key=jax.random.PRNGKey(seed))
        res, sec = timed(work)
        acc = clustering_accuracy(true, np.asarray(res.labels), 3)
        rows.append((n, t, round(sec, 4), round(live_mb(), 1),
                     int(res.n_prototypes), round(acc, 4)))
    print_csv("table7_threshold_sweep", rows,
              "n,t_star,seconds,live_mb,n_prototypes,accuracy")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        run(n=5_000, ts=(2, 4, 8))
    else:
        run(n=args.n)


if __name__ == "__main__":
    main()
