"""Pipelined streaming ingestion (DESIGN.md §18): prefetch x donation grid.

Sweeps the streaming-family executors over ``prefetch_depth`` x
``donate_stream`` against a *latency-bound* chunk source — each chunk
arrives after a fixed fetch delay (``io_ms``), modelling the out-of-core
reality the prefetcher exists for: chunks come off storage or a network
and the serial loop pays ``sum(fetch + compute)`` per chunk while the
pipelined loop pays ``max(fetch, compute)``. Every cell records wall
time, fit throughput, the peak live device-buffer footprint, and
``device_idle_frac`` — the fraction of the ingest loop the consumer spent
blocked on the source (from ``LabelSpill.ingest_stats``).

The claims under test (ISSUE 9 acceptance):

  * ``prefetch_depth >= 1`` beats the serial loop (``prefetch_depth=0``)
    on points_per_sec at the largest quick-bench n — the fetch latency is
    hidden behind device compute;
  * ``peak_mb`` stays flat across the grid — the staging pool and the
    deferred spill queue are O(depth * chunk), not O(n), so pipelining
    never trades the streaming memory contract for speed.

Results are bit-identical across every cell by construction (asserted in
tests/test_streaming.py and tests/test_distribution.py), so this harness
measures only speed, not quality.

Writes benchmarks/results/BENCH_ingest.json (schema in
docs/BENCHMARKS.md); discovered and summarized by run.py's benchmark
registry (``--bench ingest``); gated row-by-row on
points_per_sec/wall_s/peak_mb by gate.py.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

# direct-run support: repo root for the benchmarks package, src/ for repro
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import numpy as np

from benchmarks.common import live_mb, print_csv

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")

#: the grid every mode sweeps: serial reference + shallow/deep prefetch
DEPTHS = (0, 1, 3)
DONATE = (False, True)

# benchmark-registry entry (benchmarks/run.py --bench ingest)
BENCH = {
    "name": "ingest",
    "artifact": "BENCH_ingest.json",
    "summary": ("n", "points_per_sec"),
    "quick": dict(ns=(65_536,), chunk=2_048, io_ms=20.0, repeats=3,
                  mode="quick"),
    "full": lambda mx: dict(
        ns=tuple(n for n in (65_536, 262_144) if n <= mx) or (mx,),
        chunk=4_096, io_ms=20.0, repeats=3, mode="full"),
}


def _default_executors():
    execs = ["streaming"]
    if len(jax.devices()) > 1:
        execs.append("streaming_sharded")
    return tuple(execs)


def _make_blobs(n: int, d: int, k: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d), scale=4.0)
    return (centers[rng.integers(0, k, size=n)]
            + rng.normal(size=(n, d))).astype(np.float32)


def _latency_chunks(x: np.ndarray, chunk: int, io_ms: float, peak):
    """The latency-bound source: each chunk 'arrives' after ``io_ms`` of
    fetch delay (sleep releases the GIL, exactly like a disk/network read
    would), with the live device footprint sampled at every boundary."""
    for lo in range(0, len(x), chunk):
        if io_ms:
            time.sleep(io_ms / 1e3)
        peak[0] = max(peak[0], live_mb())
        yield x[lo:lo + chunk]


def run(
    ns=(65_536,),
    chunk: int = 2_048,
    io_ms: float = 20.0,
    t: int = 2,
    m: int = 2,
    d: int = 8,
    k: int = 4,
    repeats: int = 3,
    seed: int = 0,
    mode: str = "quick",
    executors=None,
):
    import repro
    from repro.core import make_data_mesh

    executors = _default_executors() if executors is None else executors
    mesh = (make_data_mesh()
            if any(e == "streaming_sharded" for e in executors) else None)
    rows = []
    for n in ns:
        x = _make_blobs(n, d, k, seed)
        for executor in executors:
            ekw = dict(mesh=mesh) if executor == "streaming_sharded" else {}
            # warm both jit families on the full stream (donating twins
            # compile separately, and the cascade/backend shapes only
            # appear at the real chunk count)
            for don in DONATE:
                repro.fit(_latency_chunks(x, chunk, 0.0, [0.0]),
                          t, m, "kmeans", k=k, executor=executor,
                          chunk_n=chunk, prefetch_depth=1, donate_stream=don,
                          key=jax.random.PRNGKey(seed), **ekw)
            for depth in DEPTHS:
                for donate in DONATE:
                    walls, idles, peaks = [], [], []
                    for _ in range(max(repeats, 1)):
                        peak = [0.0]
                        t0 = time.perf_counter()
                        res = repro.fit(
                            _latency_chunks(x, chunk, io_ms, peak), t, m,
                            "kmeans", k=k, executor=executor, chunk_n=chunk,
                            prefetch_depth=depth, donate_stream=donate,
                            key=jax.random.PRNGKey(seed), **ekw)
                        jax.block_until_ready(res.proto_labels)
                        peak[0] = max(peak[0], live_mb())
                        walls.append(time.perf_counter() - t0)
                        st = res.spill.ingest_stats
                        idles.append(st["ingest_wait_s"] / st["wall_s"]
                                     if st["wall_s"] else 0.0)
                        peaks.append(peak[0])
                        n_chunks, n_casc = res.n_chunks, res.n_cascades
                        del res
                    wall = statistics.median(walls)
                    rows.append({
                        "n": n,
                        "executor": executor,
                        "prefetch_depth": depth,
                        "donate": donate,
                        "chunks": n_chunks,
                        "cascades": n_casc,
                        "wall_s": round(wall, 4),
                        "points_per_sec": round(n / wall),
                        "peak_mb": round(max(peaks), 3),
                        "device_idle_frac": round(
                            statistics.median(idles), 4),
                    })

    print_csv(
        "ingest_pipeline",
        [(r["n"], r["executor"], r["prefetch_depth"], r["donate"],
          r["chunks"], r["wall_s"], r["points_per_sec"], r["peak_mb"],
          r["device_idle_frac"])
         for r in rows],
        "n,executor,prefetch_depth,donate,chunks,wall_s,points_per_sec,"
        "peak_mb,device_idle_frac",
    )

    os.makedirs(RESULTS, exist_ok=True)
    artifact = {
        "name": "ingest_pipeline",
        "mode": mode,
        "t": t, "m": m, "d": d, "k": k,
        "chunk_n": chunk,
        "io_ms": io_ms,
        "repeats": repeats,
        "devices": len(jax.devices()),
        "executors": list(executors),
        "recorded_unix": round(time.time(), 1),
        "rows": rows,
    }
    path = os.path.join(RESULTS, "BENCH_ingest.json")
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    print(f"# wrote {os.path.relpath(path, _REPO)}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", type=str, default="")
    ap.add_argument("--chunk", type=int, default=2_048)
    ap.add_argument("--io-ms", type=float, default=20.0,
                    help="per-chunk fetch latency the source models")
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--executors", type=str, default="",
                    help="comma list among streaming,streaming_sharded "
                         "(default: streaming, plus the composed executor "
                         "when more than one device is visible)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny sweep for CI smoke")
    args = ap.parse_args()
    executors = tuple(args.executors.split(",")) if args.executors else None
    if args.quick:
        run(ns=(8_192,), chunk=1_024, io_ms=5.0, d=2, repeats=1,
            mode="smoke", executors=executors)
        return
    ns = (tuple(int(v) for v in args.ns.split(",")) if args.ns
          else (65_536,))
    run(ns=ns, chunk=args.chunk, io_ms=args.io_ms, t=args.t, m=args.m,
        d=args.d, repeats=args.repeats, mode="cli", executors=executors)


if __name__ == "__main__":
    main()
