"""Executor matrix: wall time + peak device memory for all four planned
fit executors at fixed (t, m) over growing n.

One subprocess with a forced multi-device CPU host (the same
``--xla_force_host_platform_device_count`` trick as bench_distributed)
sweeps n and runs ``repro.fit`` once per registered executor —

  * ``memory``              — resident array, one device
  * ``sharded``             — resident array, every device
  * ``streaming``           — host chunks, one device
  * ``streaming_sharded``   — host chunks, every device (the composed path)

— recording wall-clock seconds and the peak live device-buffer footprint
(:func:`benchmarks.common.live_mb`, sampled at every chunk boundary for the
streaming family and over the resident fit for the in-memory family). The
claim under test is the planner's memory contract: both streaming columns
stay O(chunk + reservoir) — flat in n — while the in-memory columns grow
linearly with the resident array and its O(n) level maps.

Writes benchmarks/results/BENCH_fit_matrix.json (schema in
docs/BENCHMARKS.md); discovered and summarized by run.py's benchmark
registry (``--bench fit_matrix``).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

# direct-run support: repo root for the benchmarks package, src/ for repro
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_REPO, os.path.join(_REPO, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")

EXECUTORS = ("memory", "sharded", "streaming", "streaming_sharded")

# benchmark-registry entry (benchmarks/run.py --bench fit_matrix)
BENCH = {
    "name": "fit_matrix",
    "artifact": "BENCH_fit_matrix.json",
    "summary": ("n", "peak_mb"),
    "quick": dict(ns=(4_096, 8_192, 16_384), chunk=1_024, mode="quick"),
    "full": lambda mx: dict(
        ns=tuple(n for n in (16_384, 65_536, 262_144) if n <= mx) or (mx,),
        chunk=4_096, mode="full"),
}


def _child(devices: int, ns, chunk: int, t: int, m: int, d: int,
           k: int, seed: int) -> None:
    """Runs in a subprocess with ``devices`` forced CPU devices; prints one
    ``RESULT:`` JSON line per (n, executor) cell."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro
    from benchmarks.common import live_mb
    from repro.core import make_data_mesh
    from repro.data import PointStreamConfig, point_chunks

    assert len(jax.devices()) == devices, (len(jax.devices()), devices)
    mesh = make_data_mesh()

    def watched(chunks, peak):
        for c in chunks:
            peak[0] = max(peak[0], live_mb())
            yield c

    for n in ns:
        cfg = PointStreamConfig(n=n, d=d, chunk=chunk, seed=seed,
                                kind="blobs", k=k)
        for executor in EXECUTORS:
            streaming = executor.startswith("streaming")
            peak = [0.0]
            if streaming:
                data = watched(point_chunks(cfg), peak)
                kw = dict(chunk_n=chunk)
            else:
                data = jnp.asarray(np.concatenate(list(point_chunks(cfg))))
                kw = {}
            t0 = time.perf_counter()
            res = repro.fit(
                data, t, m, "kmeans", k=k, executor=executor,
                mesh=mesh if executor.endswith("sharded") else None,
                key=jax.random.PRNGKey(seed), **kw)
            jax.block_until_ready(res.proto_labels)
            sec = time.perf_counter() - t0
            # for the in-memory family the resident array + its O(n) level
            # maps are all still live right here — that IS its footprint
            peak[0] = max(peak[0], live_mb())
            labs = np.concatenate(list(res.iter_labels()))
            out = {
                "n": n,
                "executor": executor,
                "devices": devices,
                "seconds": round(sec, 4),
                "points_per_sec": round(n / sec),
                "peak_mb": round(peak[0], 3),
                "n_prototypes": int(res.n_prototypes),
                "all_assigned": bool((labs >= 0).all()),
            }
            del res, data, labs
            print("RESULT:" + json.dumps(out), flush=True)


def run(ns=(4_096, 16_384, 65_536), chunk: int = 2_048, *,
        devices: int = 8, t: int = 2, m: int = 2, d: int = 8, k: int = 4,
        seed: int = 0, mode: str = "quick") -> list:
    """Run the executor matrix in one forced-multi-device subprocess."""
    from benchmarks.common import print_csv

    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=os.pathsep.join(
            [os.path.join(_REPO, "src"), _REPO,
             os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep),
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_fit_matrix", "--_child",
         str(devices), "--ns", ",".join(str(n) for n in ns),
         "--chunk", str(chunk), "--t", str(t), "--m", str(m),
         "--d", str(d), "--k", str(k), "--seed", str(seed)],
        capture_output=True, text=True, timeout=3600, env=env, cwd=_REPO,
    )
    if proc.returncode != 0:
        print(f"# bench_fit_matrix FAILED\n{proc.stderr}", file=sys.stderr)
        return []
    rows = [json.loads(line[len("RESULT:"):])
            for line in proc.stdout.splitlines()
            if line.startswith("RESULT:")]

    print_csv(
        "fit_matrix",
        [(r["n"], r["executor"], r["devices"], r["seconds"],
          r["points_per_sec"], r["peak_mb"], r["n_prototypes"],
          r["all_assigned"]) for r in rows],
        "n,executor,devices,seconds,points_per_sec,peak_mb,"
        "n_prototypes,all_assigned",
    )

    os.makedirs(RESULTS, exist_ok=True)
    artifact = {
        "name": "fit_matrix",
        "mode": mode,
        "t": t, "m": m, "d": d, "k": k,
        "chunk_n": chunk,
        "devices": devices,
        "executors": list(EXECUTORS),
        "recorded_unix": round(time.time(), 1),
        "rows": rows,
    }
    path = os.path.join(RESULTS, "BENCH_fit_matrix.json")
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    print(f"# wrote {os.path.relpath(path, _REPO)}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--_child", type=int, default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--ns", type=str, default="")
    ap.add_argument("--chunk", type=int, default=2_048)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="tiny sweep for CI smoke")
    args = ap.parse_args()
    ns = (tuple(int(v) for v in args.ns.split(",")) if args.ns
          else (4_096, 16_384, 65_536))
    if args._child:
        _child(args._child, ns, args.chunk, args.t, args.m, args.d,
               args.k, args.seed)
        return
    if args.quick:
        run(**BENCH["quick"], devices=args.devices, t=args.t, m=args.m,
            k=args.k, seed=args.seed)
        return
    run(ns=ns, chunk=args.chunk, devices=args.devices, t=args.t, m=args.m,
        d=args.d, k=args.k, seed=args.seed, mode="cli")


if __name__ == "__main__":
    main()
