"""Paper Table 9 (Appendix B): IHTC + DBSCAN on the four smaller datasets.
ε calibrated on a 1k subsample (paper uses 10-fold CV; we use the median
4-NN distance heuristic on the subsample, same spirit)."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_DATASETS, dataset_analog, live_mb, print_csv, timed
from repro.cluster.metrics import bss_tss
from repro.core import ihtc
from repro.core.knn import knn_graph


def calibrate_eps(x: np.ndarray, seed: int = 0) -> float:
    rng = np.random.default_rng(seed)
    sub = x[rng.choice(len(x), size=min(1000, len(x)), replace=False)]
    d, _ = knn_graph(jnp.asarray(sub), 4)
    return float(np.sqrt(np.median(np.asarray(d)[:, -1])))


def run(max_n: int = 50_000, ms=(0, 1, 2)):
    rows = []
    for spec in PAPER_DATASETS[:4]:
        x = dataset_analog(spec, max_n=max_n)
        xj = jnp.asarray(x)
        eps = calibrate_eps(x)
        for m in ms:
            def work(xj=xj, m=m, eps=eps):  # bind loop vars (B023)
                return ihtc(xj, 2, m, "dbscan", eps=eps, min_pts=16.0,
                            key=jax.random.PRNGKey(2))
            res, sec = timed(work)
            lab = np.asarray(res.labels)
            k_found = int(lab.max()) + 1 if lab.max() >= 0 else 0
            ratio = float(bss_tss(xj, res.labels, max(k_found, 1)))
            noise = float((lab < 0).mean())
            rows.append((spec.name, len(x), m, round(sec, 4),
                         round(live_mb(), 1), k_found, round(ratio, 4),
                         round(noise, 3)))
    print_csv("table9_ihtc_dbscan", rows,
              "dataset,n,m,seconds,live_mb,clusters,bss_tss,noise_frac")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-n", type=int, default=50_000)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(max_n=4_000 if args.quick else args.max_n,
        ms=(1, 2) if args.quick else (0, 1, 2))


if __name__ == "__main__":
    main()
