"""Roofline table builder: aggregates the dry-run JSON artifacts
(benchmarks/results/dryrun/*.json) into the §Roofline markdown table and
ranks hillclimb candidates."""
from __future__ import annotations

import argparse
import glob
import json
import os

HEADER = (
    "| arch | shape | mesh | compute(s) | memory(s) | collective(s) | "
    "bound | useful | MFU-bound | GB/chip |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def load(results_dir: str):
    cells = []
    for fn in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    return cells


def table(cells, variant="baseline") -> str:
    lines = [HEADER]
    for c in cells:
        if c.get("variant", "baseline") != variant:
            continue
        if c["status"] == "skip":
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | "
                f"skip | — | — | — |")
            continue
        if c["status"] != "ok":
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | "
                f"ERROR | — | — | — |")
            continue
        r = c["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_term_s']:.2e} | {r['memory_term_s']:.2e} | "
            f"{r['collective_term_s']:.2e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['mfu_bound']*100:.1f}% | "
            f"{c['memory']['peak_gb']:.1f} |")
    return "\n".join(lines)


def hillclimb_candidates(cells, top: int = 5):
    ok = [c for c in cells if c["status"] == "ok"
          and c.get("variant") == "baseline"]
    by_mfu = sorted(ok, key=lambda c: c["roofline"]["mfu_bound"])[:top]
    coll = sorted(
        ok, key=lambda c: -(c["roofline"]["collective_term_s"]
                            / max(c["roofline"]["step_time_bound_s"], 1e-12)))[:top]
    return {"worst_mfu": [(c["arch"], c["shape"], c["mesh"],
                           round(c["roofline"]["mfu_bound"] * 100, 2))
                          for c in by_mfu],
            "most_collective_bound": [(c["arch"], c["shape"], c["mesh"],
                                       round(c["roofline"]["collective_term_s"], 3))
                                      for c in coll]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    cells = load(args.dir)
    print(table(cells, args.variant))
    print()
    print(json.dumps(hillclimb_candidates(cells), indent=1))


if __name__ == "__main__":
    main()
